"""L2 model tests: census field semantics, padding invariance, AOT lowering."""

import jax
import jax.numpy as jnp
import numpy as np
from _hypo import given, settings, strategies as st

from compile import aot, model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

IDX = {name: i for i, name in enumerate(model.STATS_FIELDS)}


def random_adjacency(n, density, seed):
    rng = np.random.default_rng(seed)
    a = (rng.random((n, n)) < density).astype(np.float32)
    a = np.triu(a, 1)
    return a + a.T


def test_stats_layout_matches_ref():
    a = jnp.asarray(random_adjacency(32, 0.2, seed=7))
    stats, deg = model.census(a, block=8)
    stats_ref, deg_ref = ref.census_ref(a)
    np.testing.assert_allclose(np.asarray(stats), np.asarray(stats_ref), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(deg), np.asarray(deg_ref), rtol=1e-5)


def test_known_small_graph():
    # Path 0-1-2 plus triangle 3-4-5.
    a = np.zeros((8, 8), np.float32)
    for u, v in [(0, 1), (1, 2), (3, 4), (4, 5), (3, 5)]:
        a[u, v] = a[v, u] = 1.0
    stats, deg = model.census(jnp.asarray(a), block=4)
    s = np.asarray(stats)
    assert s[IDX["n_active"]] == 6
    assert s[IDX["edges"]] == 5
    assert s[IDX["triangles"]] == 1
    # wedges: vertex 1 contributes C(2,2)=1; each triangle vertex 1 -> 3+1.
    assert s[IDX["wedges"]] == 4
    assert s[IDX["max_deg"]] == 2
    assert s[IDX["sum_deg"]] == 10


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_padding_invariance(seed):
    """Zero-padding a graph into a larger tile never changes the census."""
    a_small = random_adjacency(16, 0.3, seed)
    a_big = np.zeros((32, 32), np.float32)
    a_big[:16, :16] = a_small
    s_small, _ = model.census(jnp.asarray(a_small), block=8)
    s_big, _ = model.census(jnp.asarray(a_big), block=8)
    np.testing.assert_allclose(np.asarray(s_small), np.asarray(s_big), rtol=1e-5)


def test_aot_lowering_produces_hlo_text(tmp_path):
    rc = aot.main(["--out-dir", str(tmp_path), "--sizes", "64"])
    assert rc == 0
    hlo = (tmp_path / "census_64.hlo.txt").read_text()
    assert "HloModule" in hlo
    # Tuple-rooted (return_tuple=True), so the Rust side can unwrap it.
    manifest = (tmp_path / "manifest.txt").read_text().strip().split()
    assert manifest[0] == "census_64" and manifest[1] == "64"


def test_aot_selfcheck_catches_layout():
    """lower_census returns a lowering whose execution matches the oracle."""
    lowered, block = aot.lower_census(64)
    compiled = lowered.compile()
    a = random_adjacency(64, 0.1, seed=3)
    stats, deg = compiled(jnp.asarray(a))
    stats_ref, deg_ref = ref.census_ref(jnp.asarray(a))
    np.testing.assert_allclose(np.asarray(stats), np.asarray(stats_ref), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(deg), np.asarray(deg_ref), rtol=1e-5)

"""Pin python/tools/comm_model_sim.py — the independent twin of the Rust
recovery-protocol checker (rust/src/comm/comm_model.rs). Both explore the
same bounded model; this suite pins the exact state-space sizes and
outcomes the Rust tests pin, so a divergence in either implementation
breaks one suite without the other and points at the drifting side."""

import importlib.util
import sys
from pathlib import Path

import pytest

_TOOL = Path(__file__).resolve().parents[1] / "tools" / "comm_model_sim.py"
_spec = importlib.util.spec_from_file_location("comm_model_sim", _TOOL)
sim = importlib.util.module_from_spec(_spec)
# Registered so the dataclass machinery can resolve the module's own
# (string, via __future__ annotations) field types at class-build time.
sys.modules[_spec.name] = sim
_spec.loader.exec_module(sim)


def run(shards, steps, budget, faults=(), mutation="none"):
    return sim.check(sim.Config(shards, steps, budget, tuple(faults), mutation))


# Exact (states, transitions, terminals, max_depth) per fault-free
# (shards, steps) — the budget never enters a fault-free space. The Rust
# checker pins the same table in
# comm_model::tests::fault_free_matrix_completes_and_matches_python_pins.
FAULT_FREE_PINS = {
    (2, 1): (17, 24, 1, 8),
    (2, 2): (25, 36, 1, 12),
    (2, 3): (33, 48, 1, 16),
    (3, 1): (53, 108, 1, 12),
    (3, 2): (79, 162, 1, 18),
    (3, 3): (105, 216, 1, 24),
}


def test_fault_free_matrix_matches_rust_pins():
    for (shards, steps), want in FAULT_FREE_PINS.items():
        for budget in (0, 1, 2):
            rep = run(shards, steps, budget)
            assert rep.outcome == ("completed", 0, 0)
            assert (rep.states, rep.transitions, rep.terminals, rep.max_depth) == want


def test_single_fault_canonical_config_matches_rust_pins():
    rep = run(2, 2, 1, [sim.Fault(1, 2)])
    assert rep.outcome == ("completed", 1, 1)
    assert (rep.states, rep.transitions, rep.terminals, rep.max_depth) == (31, 46, 1, 14)


def test_double_fault_three_shards_matches_rust_pins():
    rep = run(3, 3, 2, [sim.Fault(1, 2), sim.Fault(0, 2)])
    assert rep.outcome == ("completed", 2, 1)
    assert (rep.states, rep.transitions, rep.terminals, rep.max_depth) == (153, 332, 1, 28)


def test_exhaustion_terminals_match_rust_pins():
    rep = run(2, 2, 2, [sim.Fault(1, 2, repeat=True)])
    assert rep.outcome == ("exhausted",)
    assert (rep.states, rep.transitions, rep.terminals) == (29, 42, 3)
    rep = run(2, 1, 0, [sim.Fault(0, 1)])
    assert rep.outcome == ("exhausted",)
    assert (rep.states, rep.terminals) == (9, 3)


def test_exhaustive_single_fault_matrix_totals_match_rust():
    """The full 540-configuration matrix the ISSUE demands. The summed
    space is pinned bit-for-bit against the Rust checker's matrix test —
    the strongest cross-validation the two implementations share."""
    runs = states = transitions = completed = largest = 0
    for n in (2, 3):
        for steps in (1, 2, 3):
            for budget in (0, 1, 2):
                for shard in range(n):
                    for step in range(1, steps + 2):
                        for repeat in (False, True):
                            for at_send in (False, True):
                                rep = run(
                                    n, steps, budget,
                                    [sim.Fault(shard, step, repeat, at_send)],
                                )
                                want_completed = not repeat and budget >= 1
                                assert (rep.outcome[0] == "completed") == want_completed
                                if want_completed:
                                    assert rep.outcome == (
                                        "completed", 1, 1 if step <= steps else 0,
                                    )
                                runs += 1
                                states += rep.states
                                transitions += rep.transitions
                                largest = max(largest, rep.states)
                                completed += rep.outcome[0] == "completed"
    assert (runs, states, transitions, completed, largest) == (540, 28999, 54195, 180, 141)


def test_multi_fault_plans_match_rust_pins():
    cases = [
        (2, 2, 2, [sim.Fault(0, 2), sim.Fault(1, 2)], 41, 64, ("completed", 2, 1)),
        (2, 2, 2, [sim.Fault(1, 1), sim.Fault(1, 2)], 31, 46, ("completed", 1, 1)),
        (2, 3, 2, [sim.Fault(0, 1), sim.Fault(1, 3)], 45, 68, ("completed", 2, 2)),
        (2, 2, 1, [sim.Fault(0, 3)], 31, 46, ("completed", 1, 0)),
        (2, 2, 2, [sim.Fault(0, 1, at_send=True), sim.Fault(1, 2)], 34, 51, ("completed", 2, 2)),
    ]
    for shards, steps, budget, faults, want_states, want_trans, want_outcome in cases:
        rep = run(shards, steps, budget, faults)
        assert (rep.states, rep.transitions, rep.outcome) == (
            want_states, want_trans, want_outcome,
        ), faults


@pytest.mark.parametrize(
    "mutation,needle",
    [
        ("stale-restore", "expected the step-1 checkpoint"),
        ("skip-restore", "expected the step-1 checkpoint"),
        ("keep-oneshot", "oracle expected completion"),
        ("rebroadcast", "re-ran step"),
    ],
)
def test_seeded_mutations_are_caught(mutation, needle):
    # Fault at step 2: at step 1 the empty snapshot is legitimately
    # correct, so the restore mutations would be invisible there.
    with pytest.raises(sim.Violation, match=needle):
        run(2, 2, 1, [sim.Fault(1, 2)], mutation=mutation)


def test_fault_parser_roundtrip_and_rejects():
    assert sim.parse_fault("shard=1,step=2") == sim.Fault(1, 2)
    assert sim.parse_fault("shard=0,step=3,repeat,send") == sim.Fault(0, 3, True, True)
    with pytest.raises(ValueError):
        sim.parse_fault("shard=1")
    with pytest.raises(ValueError):
        sim.parse_fault("shard=1,step=2,loud")

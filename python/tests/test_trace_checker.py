"""Pin python/tools/check_trace.py — the validator CI's "Trace smoke"
step trusts — against handwritten good and broken documents. Each bad
fixture flips exactly one property, so a checker regression that stops
catching it fails here first, not silently in CI."""

import importlib.util
import json
from pathlib import Path

_TOOL = Path(__file__).resolve().parents[1] / "tools" / "check_trace.py"
_spec = importlib.util.spec_from_file_location("check_trace", _TOOL)
check_trace = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_trace)


def _ev(ph, name, pid=0, tid=0, ts=0.0, **extra):
    e = {"ph": ph, "name": name, "cat": "engine", "pid": pid, "tid": tid, "ts": ts}
    e.update(extra)
    return e


def good_trace(recovery=False):
    """Minimal document with the shape the Rust exporter emits."""
    events = [
        {"ph": "M", "name": "process_name", "args": {"name": "coordinator"}},
        _ev("B", "Step", ts=1.0),
        _ev("B", "Merge", ts=2.0),
        _ev("E", "Merge", ts=3.0),
        _ev("E", "Step", ts=4.0),
        _ev("B", "Extract", tid=1, ts=1.5),
        _ev("E", "Extract", tid=1, ts=2.5),
    ]
    if recovery:
        for pid in (1, 2):
            events += [_ev("B", "Step", pid=pid, ts=1.0), _ev("E", "Step", pid=pid, ts=2.0)]
        for name in ("FailureDetected", "Respawn", "Replay", "Restore"):
            events += [_ev("B", name, ts=5.0), _ev("E", name, ts=6.0)]
    return {"traceEvents": events, "otherData": {"droppedSpans": 0, "wireChecks": 0}}


def good_metrics():
    return {
        "counters": {"step1/processed": 10, "total/processed": 10, "trace/spans": 7},
        "meta": {"schema": "arabesque-metrics-v1", "steps": 1},
    }


def test_good_trace_passes():
    assert check_trace.validate_trace(good_trace()) == []


def test_good_recovery_trace_passes():
    assert check_trace.validate_trace(good_trace(recovery=True), expect_recovery=True) == []


def test_unclosed_span_is_caught():
    t = good_trace()
    t["traceEvents"] = [e for e in t["traceEvents"] if not (e["ph"] == "E" and e["name"] == "Step")]
    errs = check_trace.validate_trace(t)
    assert any("unclosed" in e for e in errs), errs


def test_mismatched_close_is_caught():
    t = good_trace()
    # Swap the two closers: Step now "closes" the inner Merge.
    evs = t["traceEvents"]
    i, j = 3, 4
    assert (evs[i]["name"], evs[j]["name"]) == ("Merge", "Step")
    evs[i], evs[j] = evs[j], evs[i]
    errs = check_trace.validate_trace(t)
    assert any("does not close innermost" in e for e in errs), errs


def test_end_before_start_is_caught():
    t = good_trace()
    for e in t["traceEvents"]:
        if e["ph"] == "E" and e["name"] == "Merge":
            e["ts"] = 0.5  # its B opened at 2.0
    errs = check_trace.validate_trace(t)
    assert any("before start" in e for e in errs), errs


def test_bad_phase_and_missing_fields_are_caught():
    t = good_trace()
    t["traceEvents"].append({"ph": "X", "name": "wat"})
    t["traceEvents"].append({"ph": "B", "name": "Step", "pid": "zero", "tid": 0, "ts": 1})
    errs = check_trace.validate_trace(t)
    assert any("bad phase" in e for e in errs), errs
    assert any("pid/tid must be integers" in e for e in errs), errs


def test_missing_top_level_keys_are_caught():
    assert check_trace.validate_trace({}) == ["missing 'traceEvents' array"]
    errs = check_trace.validate_trace({"traceEvents": []})
    assert any("droppedSpans" in e for e in errs), errs


def test_recovery_expectation_requires_all_pids_and_spans():
    # A clean trace that never recovered must FAIL under --expect-recovery.
    errs = check_trace.validate_trace(good_trace(), expect_recovery=True)
    assert any("no spans from pid 1" in e for e in errs), errs
    assert any("'Respawn'" in e for e in errs), errs
    # Dropping one recovery span kind from an otherwise-complete trace fails.
    t = good_trace(recovery=True)
    t["traceEvents"] = [e for e in t["traceEvents"] if e["name"] != "Replay"]
    errs = check_trace.validate_trace(t, expect_recovery=True)
    assert errs == ["expected recovery run: no 'Replay' span"]


def test_good_metrics_pass():
    assert check_trace.validate_metrics(good_metrics()) == []


def test_metrics_schema_and_counters_enforced():
    m = good_metrics()
    m["meta"]["schema"] = "v0"
    assert any("meta.schema" in e for e in check_trace.validate_metrics(m))
    m = good_metrics()
    del m["counters"]["total/processed"]
    assert any("total/processed" in e for e in check_trace.validate_metrics(m))
    m = good_metrics()
    m["counters"] = {"total/processed": "ten"}
    errs = check_trace.validate_metrics(m)
    assert any("not a number" in e for e in errs), errs
    assert check_trace.validate_metrics({"counters": {}}) != []


def test_cli_exit_codes(tmp_path):
    trace = tmp_path / "trace.json"
    metrics = tmp_path / "metrics.json"
    trace.write_text(json.dumps(good_trace(recovery=True)))
    metrics.write_text(json.dumps(good_metrics()))
    assert (
        check_trace.main([str(trace), "--metrics", str(metrics), "--expect-recovery"]) == 0
    )
    # A truncated file is a load error, not a crash.
    bad = tmp_path / "bad.json"
    bad.write_text('{"traceEvents": [')
    assert check_trace.main([str(bad)]) == 1
    # A valid-but-unrecovered trace fails only under --expect-recovery.
    plain = tmp_path / "plain.json"
    plain.write_text(json.dumps(good_trace()))
    assert check_trace.main([str(plain)]) == 0
    assert check_trace.main([str(plain), "--expect-recovery"]) == 1

"""Pallas census kernel vs pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps shapes, block sizes, densities, and dtypes; every case
asserts allclose against kernels/ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, strategies as st

from compile.kernels import census, ref

jax.config.update("jax_platform_name", "cpu")


def random_adjacency(n, density, seed, dtype=np.float32):
    rng = np.random.default_rng(seed)
    a = (rng.random((n, n)) < density).astype(dtype)
    a = np.triu(a, 1)
    return a + a.T


# ---------------------------------------------------------------------------
# Deterministic unit tests
# ---------------------------------------------------------------------------


def test_empty_graph():
    a = jnp.zeros((8, 8), jnp.float32)
    out = census.masked_matmul_reduce(a, block=4)
    assert out.shape == (2, 2)
    np.testing.assert_allclose(np.asarray(out), 0.0)


def test_single_triangle():
    a = np.zeros((8, 8), np.float32)
    for u, v in [(0, 1), (1, 2), (0, 2)]:
        a[u, v] = a[v, u] = 1.0
    t = census.triangle_count(jnp.asarray(a), block=4)
    assert float(t) == 1.0


def test_complete_graph_k6():
    n = 8
    a = np.ones((n, n), np.float32) - np.eye(n, dtype=np.float32)
    a[6:, :] = 0.0
    a[:, 6:] = 0.0  # K6 embedded in an 8x8 tile (2 padding vertices)
    t = census.triangle_count(jnp.asarray(a), block=4)
    assert float(t) == 20.0  # C(6,3)


def test_block_equals_n():
    a = random_adjacency(16, 0.3, seed=1)
    out = census.masked_matmul_reduce(jnp.asarray(a), block=16)
    assert out.shape == (1, 1)
    np.testing.assert_allclose(
        np.asarray(out),
        np.asarray(ref.masked_matmul_reduce_ref(jnp.asarray(a), 16)),
        rtol=1e-5,
    )


def test_rejects_non_square():
    with pytest.raises(ValueError, match="square"):
        census.masked_matmul_reduce(jnp.zeros((4, 8), jnp.float32), block=4)


def test_rejects_indivisible_block():
    with pytest.raises(ValueError, match="multiple"):
        census.masked_matmul_reduce(jnp.zeros((12, 12), jnp.float32), block=8)


def test_pick_block():
    assert census.pick_block(256) == 128
    assert census.pick_block(1024) == 128
    assert census.pick_block(96) == 32
    assert census.pick_block(8) == 8


# ---------------------------------------------------------------------------
# Hypothesis sweeps: shapes x blocks x densities x dtypes
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(
    n_b=st.integers(min_value=1, max_value=4),
    block=st.sampled_from([4, 8, 16]),
    density=st.floats(min_value=0.0, max_value=0.9),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_kernel_matches_ref(n_b, block, density, seed):
    n = n_b * block
    a = jnp.asarray(random_adjacency(n, density, seed))
    got = census.masked_matmul_reduce(a, block=block)
    want = ref.masked_matmul_reduce_ref(a, block)
    assert got.shape == (n_b, n_b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([8, 16, 32, 64]),
    density=st.floats(min_value=0.05, max_value=0.6),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_triangle_count_matches_ref(n, density, seed):
    a = jnp.asarray(random_adjacency(n, density, seed))
    got = census.triangle_count(a)
    want = ref.triangle_count_ref(a)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    dtype=st.sampled_from([np.float32, np.int32, np.float64]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_kernel_dtypes(dtype, seed):
    """Non-f32 adjacency inputs accumulate in f32 and match the oracle."""
    a = random_adjacency(16, 0.3, seed, dtype=dtype)
    got = census.masked_matmul_reduce(jnp.asarray(a), block=8)
    want = ref.masked_matmul_reduce_ref(jnp.asarray(a), 8)
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want, np.float32), rtol=1e-4
    )


@settings(max_examples=15, deadline=None)
@given(
    n_b=st.integers(min_value=1, max_value=3),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_triangle_count_nonnegative_integer(n_b, seed):
    """Triangle counts of 0/1 adjacency matrices are exact integers."""
    a = jnp.asarray(random_adjacency(8 * n_b, 0.4, seed))
    t = float(census.triangle_count(a, block=8))
    assert t >= 0.0
    assert abs(t - round(t)) < 1e-3

"""`hypothesis` pass-through with a deterministic offline fallback.

CI installs real hypothesis and gets full shrinking/edge-case search;
the offline dev container must not pip-install anything, so when the
import fails this shim provides the small subset these tests use —
``@given``/``@settings`` plus the ``integers``/``floats``/``sampled_from``
strategies — driven by a PRNG seeded from the test name, so every run
and every machine sees the same cases and failures reproduce.
"""

try:  # pragma: no cover - prefer the real library when present
    from hypothesis import given, settings, strategies  # noqa: F401
except ModuleNotFoundError:  # pragma: no cover - offline fallback
    import random

    _DEFAULT_EXAMPLES = 25

    class _Strategy:
        def __init__(self, sample, boundary=None):
            self._sample = sample
            # Boundary values tried before random sampling (cheap
            # stand-in for hypothesis' edge-case bias).
            self._boundary = list(boundary or [])

        def draw(self, rnd, index):
            if index < len(self._boundary):
                return self._boundary[index]
            return self._sample(rnd)

    class strategies:  # noqa: N801 - mimics the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda r: r.randint(min_value, max_value),
                boundary=[min_value, max_value],
            )

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda r: r.uniform(min_value, max_value),
                boundary=[min_value, max_value],
            )

        @staticmethod
        def sampled_from(items):
            seq = list(items)
            return _Strategy(lambda r: r.choice(seq), boundary=seq[:1])

    def given(**strats):
        def deco(fn):
            def wrapper():
                examples = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
                for i in range(examples):
                    rnd = random.Random(f"{fn.__module__}.{fn.__name__}:{i}")
                    kwargs = {k: s.draw(rnd, i) for k, s in strats.items()}
                    try:
                        fn(**kwargs)
                    except Exception as e:
                        raise AssertionError(
                            f"falsifying example (offline shim, case {i}): {kwargs!r}"
                        ) from e

            # No functools.wraps: pytest would follow __wrapped__ to the
            # parameterized original and demand fixtures for its args.
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper

        return deco

    def settings(max_examples=_DEFAULT_EXAMPLES, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

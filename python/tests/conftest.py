"""Test wiring: make `compile` (python/compile) and the local
`_hypo` shim importable regardless of the pytest invocation directory."""

import sys
from pathlib import Path

_HERE = Path(__file__).resolve().parent
for p in (str(_HERE), str(_HERE.parent)):
    if p not in sys.path:
        sys.path.insert(0, p)

//! Quickstart: mine cliques on a small synthetic graph with 4 workers.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use arabesque::apps::Cliques;
use arabesque::engine::{Cluster, Config};
use arabesque::graph::gen;
use arabesque::output::MemorySink;

fn main() {
    // A synthetic CiteSeer-shaped graph (paper Table 1: 3,312 vertices,
    // 4,732 edges, 6 labels).
    let g = gen::dataset("citeseer", 1.0).expect("known dataset");
    println!("input: {g:?}");

    // 2 simulated servers x 2 threads, defaults otherwise (ODAG frontier
    // storage + two-level pattern aggregation on).
    let cluster = Cluster::new(Config::new(2, 2));
    let sink = Arc::new(MemorySink::new());
    let result = cluster.run_with_sink(&g, &Cliques::new(4), sink.clone());

    println!(
        "explored {} embeddings over {} steps in {:.3}s",
        result.processed,
        result.steps.len(),
        result.wall.as_secs_f64()
    );
    println!("found {} cliques (sizes 2..=4):", result.num_outputs);
    for line in sink.sorted().iter().take(5) {
        println!("  {line}");
    }
    println!("  ... ({} total)", result.num_outputs);
}

//! Frequent subgraph mining walkthrough (paper §4.2): a support-
//! threshold sweep on the synthetic CiteSeer graph, with the centralized
//! baseline cross-check and a look at the domain/support machinery.
//!
//! ```text
//! cargo run --release --example fsm_mining
//! ```

use std::sync::Arc;

use arabesque::apps::Fsm;
use arabesque::baselines::centralized::CentralizedFsm;
use arabesque::engine::{Cluster, Config};
use arabesque::graph::gen;
use arabesque::output::MemorySink;
use arabesque::util::human_secs;

fn main() -> arabesque::util::err::Result<()> {
    let g = gen::dataset("citeseer", 1.0)?;
    println!("input: {g:?}\n");
    let max_edges = 3;

    println!(
        "{:>8} {:>10} {:>14} {:>12} {:>10}",
        "support", "frequent", "embeddings", "steps", "wall"
    );
    for support in [400usize, 200, 100, 50] {
        let app = Fsm::new(support).with_max_edges(max_edges);
        let sink = Arc::new(MemorySink::new());
        let r = Cluster::new(Config::new(2, 4)).run_with_sink(&g, &app, sink.clone());
        let frequent = sink
            .sorted()
            .iter()
            .filter(|l| l.starts_with("frequent pattern"))
            .count();
        println!(
            "{:>8} {:>10} {:>14} {:>12} {:>10}",
            support,
            frequent,
            r.processed,
            r.steps.len(),
            human_secs(r.wall.as_secs_f64())
        );
    }

    // Cross-check one threshold against the centralized pattern-growth
    // implementation (the GRAMI stand-in).
    let support = 100;
    let app = Fsm::new(support).with_max_edges(max_edges);
    let sink = Arc::new(MemorySink::new());
    Cluster::new(Config::new(1, 4)).run_with_sink(&g, &app, sink.clone());
    let mut arabesque_patterns: Vec<String> = sink
        .sorted()
        .into_iter()
        .filter(|l| l.starts_with("frequent pattern"))
        .collect();
    arabesque_patterns.sort();

    let cen = CentralizedFsm::new(support, max_edges).run(&g);
    println!(
        "\ncross-check at support={support}: arabesque={} centralized={}",
        arabesque_patterns.len(),
        cen.len()
    );
    // Compare the exact (pattern, support) sets.
    let mut cen_lines: Vec<String> = cen
        .iter()
        .map(|f| format!("frequent pattern {} support={}", f.pattern, f.support))
        .collect();
    cen_lines.sort();
    assert_eq!(
        arabesque_patterns, cen_lines,
        "engine and centralized baseline disagree"
    );
    println!("MATCH: both implementations find the same frequent patterns");

    println!("\nfrequent patterns at support={support}:");
    for line in arabesque_patterns.iter().take(10) {
        println!("  {line}");
    }
    Ok(())
}

//! End-to-end driver: proves all layers compose on a real small
//! workload, per-paper-style reporting. The measurement surface and
//! recorded trajectory live in rust/benches/README.md.
//!
//! Pipeline exercised:
//!   1. generate the scaled dataset suite (synthetic stand-ins, Table 1);
//!   2. run the three paper applications (FSM / Motifs / Cliques) on the
//!      simulated multi-server cluster, scaling 1 -> 8 workers;
//!   3. cross-validate Motifs MS=3 against the AOT PJRT census (the
//!      L1 Pallas kernel inside the L2 JAX model, loaded from
//!      artifacts/ and executed through the Rust runtime);
//!   4. cross-validate FSM against the centralized baseline;
//!   5. report the paper's headline metrics: embeddings explored,
//!      speedups, ODAG compression, message counts.
//!
//! ```text
//! make artifacts && cargo run --release --example end_to_end
//! ```

use std::sync::Arc;
use std::time::Duration;

use arabesque::apps::{Cliques, Fsm, Motifs};
use arabesque::baselines::centralized::CentralizedFsm;
use arabesque::engine::{Cluster, Config, RunResult};
use arabesque::graph::gen;
use arabesque::output::MemorySink;
use arabesque::runtime::{CensusExecutor, Motif3Counts};
use arabesque::util::{human_bytes, human_count, human_secs};
use arabesque::GraphMiningApp;

fn run(g: &arabesque::LabeledGraph, app: &dyn GraphMiningApp, servers: usize, threads: usize) -> RunResult {
    Cluster::new(Config::new(servers, threads)).run(g, app)
}

use arabesque::util::err::Result;

/// L1/L2 cross-validation: the AOT PJRT census against the engine and
/// the enumeration oracle. Only a failed *load* (no `pjrt` feature, no
/// artifacts) is treated as a skip by the caller; once an executor
/// exists, any census failure propagates and fails the example.
fn pjrt_crosscheck(exec: &CensusExecutor) -> Result<()> {
    println!("PJRT platform: {}", exec.platform());
    let probe = gen::dataset("citeseer", 0.07)?.unlabeled(); // fits the 256 tile
    let stats = exec.census(&probe)?;
    let pjrt = Motif3Counts::from_stats(&stats);
    let r = run(&probe, &Motifs::new(3), 1, 4);
    let engine_total: i64 = r.aggregates.pattern_output.values().map(|v| v.as_long()).sum();
    println!(
        "census: chains={} triangles={} | engine motif-3 total={}",
        pjrt.chains, pjrt.triangles, engine_total
    );
    assert_eq!(engine_total as u64, pjrt.chains + pjrt.triangles);
    assert_eq!(pjrt, Motif3Counts::by_enumeration(&probe));
    println!("MATCH");
    Ok(())
}

fn main() -> Result<()> {
    println!("=== Arabesque end-to-end driver ===\n");

    // ---- 1. datasets ------------------------------------------------
    let citeseer = gen::dataset("citeseer", 1.0)?;
    let mico_s = gen::dataset("mico-s", 1.0)?;
    let youtube_s = gen::dataset("youtube-s", 1.0)?;
    for (n, g) in [("citeseer", &citeseer), ("mico-s", &mico_s), ("youtube-s", &youtube_s)] {
        println!("dataset {n}: {g:?}");
    }
    // Motifs/Cliques are structural problems: the paper treats their
    // input as unlabeled (§2; Table 4 shows e.g. 3 quick patterns for
    // Motifs-MiCo MS=3).
    let mico_u = mico_s.unlabeled();
    let youtube_u = youtube_s.unlabeled();

    // ---- 2. the three applications, scaling workers -----------------
    // This testbed has ONE core, so scalability uses simulated BSP time
    // (per step: busiest worker + coordinator merge), exactly what the
    // barrier yields on a real cluster. See ARCHITECTURE.md "Substitutions".
    println!("\n--- scaling (1 worker -> 8 workers, simulated BSP time) ---");
    println!(
        "{:<22} {:>14} {:>10} {:>10} {:>8}",
        "app-graph", "embeddings", "T(1w)", "T(8w)", "speedup"
    );
    let mut total_embeddings = 0u64;
    let apps: Vec<(&str, Box<dyn GraphMiningApp>, &arabesque::LabeledGraph)> = vec![
        ("motifs-mico-s", Box::new(Motifs::new(3)), &mico_u),
        ("cliques-mico-s", Box::new(Cliques::new(4)), &mico_u),
        ("fsm-citeseer", Box::new(Fsm::new(100).with_max_edges(3)), &citeseer),
        ("motifs-youtube-s", Box::new(Motifs::new(3)), &youtube_u),
    ];
    for (name, app, g) in &apps {
        let r1 = run(g, app.as_ref(), 1, 1);
        let r8 = run(g, app.as_ref(), 2, 4);
        assert_eq!(r1.processed, r8.processed, "{name}: worker count changed results");
        total_embeddings += r8.processed;
        println!(
            "{:<22} {:>14} {:>10} {:>10} {:>7.1}x",
            name,
            human_count(r8.processed),
            human_secs(r1.sim_wall.as_secs_f64()),
            human_secs(r8.sim_wall.as_secs_f64()),
            r1.sim_wall.as_secs_f64() / r8.sim_wall.as_secs_f64().max(1e-9),
        );
    }

    // ---- 3. Motifs vs the AOT PJRT census ---------------------------
    println!("\n--- L1/L2 cross-validation: PJRT census vs engine ---");
    match CensusExecutor::load_default() {
        Ok(exec) => pjrt_crosscheck(&exec)?,
        Err(e) => {
            println!("skipped: {e}");
            println!("(needs the `pjrt` feature + an `xla` dependency + `make artifacts`)");
        }
    }

    // ---- 4. FSM vs centralized baseline ------------------------------
    println!("\n--- FSM cross-validation: engine vs centralized ---");
    let sink = Arc::new(MemorySink::new());
    let app = Fsm::new(100).with_max_edges(3);
    Cluster::new(Config::new(2, 2)).run_with_sink(&citeseer, &app, sink.clone());
    let engine_frequent = sink
        .sorted()
        .iter()
        .filter(|l| l.starts_with("frequent pattern"))
        .count();
    let cen = CentralizedFsm::new(100, 3).run(&citeseer);
    println!("engine: {engine_frequent} frequent patterns | centralized: {}", cen.len());
    assert_eq!(engine_frequent, cen.len());
    println!("MATCH");

    // ---- 5. headline metrics ----------------------------------------
    println!("\n--- headline metrics ---");
    let r = run(&mico_u, &Motifs::new(3), 2, 4);
    let odag_bytes: u64 = r.steps.iter().map(|s| s.frontier_bytes).max().unwrap_or(0);
    let list_bytes: u64 = r.steps.iter().map(|s| s.list_bytes).max().unwrap_or(0);
    println!("total embeddings explored (suite): {}", human_count(total_embeddings));
    println!(
        "motifs-mico-s frontier: ODAG {} vs list {} ({:.1}x compression)",
        human_bytes(odag_bytes),
        human_bytes(list_bytes),
        list_bytes as f64 / odag_bytes.max(1) as f64
    );
    println!(
        "motifs-mico-s comms: {} messages, {} across servers",
        human_count(r.comm.messages),
        human_bytes(r.comm.bytes)
    );
    println!(
        "aggregation: {} embeddings mapped -> {} quick patterns -> {} canonize calls",
        human_count(r.agg_stats.mapped),
        human_count(r.agg_stats.quick_patterns),
        human_count(r.agg_stats.canonize_calls)
    );
    if let Some(rss) = arabesque::stats::peak_rss_bytes() {
        println!("peak rss: {}", human_bytes(rss));
    }
    let wall: Duration = r.wall;
    println!("\nend-to-end OK in {}", human_secs(wall.as_secs_f64()));
    Ok(())
}

//! L1/L2/L3 integration demo: run the AOT-compiled PJRT census (Pallas
//! kernel inside a JAX model, lowered to HLO text, executed from Rust)
//! against the enumeration engine's motif-3 counts on several graphs.
//!
//! Requires `make artifacts`.
//!
//! ```text
//! cargo run --release --example motif_census
//! ```

use arabesque::apps::Motifs;
use arabesque::engine::{Cluster, Config};
use arabesque::graph::gen;
use arabesque::runtime::{CensusExecutor, Motif3Counts};

fn main() -> arabesque::util::err::Result<()> {
    let exec = match CensusExecutor::load_default() {
        Ok(e) => e,
        Err(e) => {
            println!("skipping motif census: {e}");
            println!("(needs the `pjrt` feature + an `xla` dependency + `make artifacts`)");
            return Ok(());
        }
    };
    println!(
        "PJRT platform: {} | census tiles up to {} vertices",
        exec.platform(),
        exec.max_vertices()
    );

    for (name, scale) in [("citeseer", 0.07), ("mico", 0.005), ("youtube", 0.0002)] {
        // Motif mining assumes unlabeled input (paper §2); the census is
        // label-free by construction.
        let g = gen::dataset(name, scale)?.unlabeled();
        if g.num_vertices() > exec.max_vertices() {
            println!("{name}: skipped ({} vertices > max tile)", g.num_vertices());
            continue;
        }

        // PJRT path: dense adjacency tile -> AOT census.
        let t0 = std::time::Instant::now();
        let stats = exec.census(&g)?;
        let pjrt = Motif3Counts::from_stats(&stats);
        let t_pjrt = t0.elapsed();

        // Enumeration path: the Arabesque engine counting motif-3.
        let t1 = std::time::Instant::now();
        let r = Cluster::new(Config::new(1, 4)).run(&g, &Motifs::new(3));
        let t_engine = t1.elapsed();
        let mut engine_counts: Vec<(String, i64)> = r
            .aggregates
            .pattern_output
            .iter()
            .map(|(p, v)| (p.to_string(), v.as_long()))
            .collect();
        engine_counts.sort();
        let engine_total: i64 = engine_counts.iter().map(|(_, c)| c).sum();

        let enumerated = Motif3Counts::by_enumeration(&g);
        println!("\n{name} ({g:?})");
        println!(
            "  PJRT census : edges={} chains={} triangles={}  [{:?}]",
            pjrt.edges, pjrt.chains, pjrt.triangles, t_pjrt
        );
        println!(
            "  exact oracle: edges={} chains={} triangles={}",
            enumerated.edges, enumerated.chains, enumerated.triangles
        );
        println!(
            "  engine      : motif-3 embeddings={engine_total} over {} patterns  [{:?}]",
            engine_counts.len(),
            t_engine
        );
        assert_eq!(pjrt, enumerated, "PJRT census must match enumeration");
        assert_eq!(
            engine_total as u64,
            pjrt.chains + pjrt.triangles,
            "engine motif total must match the census"
        );
        println!("  MATCH");
    }
    Ok(())
}
